"""Paper Figs. 5-7 analog, MEASURED on this host: naive vs Kahan dot
throughput across working-set sizes spanning the cache hierarchy.

The paper's claim — compensation is free once the loop is bandwidth-bound —
is hardware-independent; this benchmark reproduces it on the container's
x86 core with XLA-compiled kernels: a SIMD-vectorized compensated dot
(lane-parallel Neumaier, the Pallas kernel's algorithm in jnp form) vs
jnp.dot. In-cache the compensated version pays its ~4× arithmetic; as the
working set leaves LLC the ratio collapses toward 1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

LANES = 4096  # wide lanes so XLA vectorizes the compensated inner ops


@jax.jit
def _naive_dot(x, y):
    return jnp.dot(x, y)


@jax.jit
def _kahan_dot_lanes(x2, y2):
    """Lane-parallel compensated dot: scan rows, (sum, carry) per lane."""
    from repro.core import kahan

    def body(carry, xy):
        s, c = carry
        xi, yi = xy
        return kahan.neumaier_step(s, c, xi * yi), None

    zeros = jnp.zeros((x2.shape[1],), jnp.float32)
    (s, c), _ = jax.lax.scan(body, (zeros, zeros), (x2, y2))
    return jnp.sum(s + c)


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6   # us


def run() -> list[tuple]:
    rows = []
    for n in (1 << 12, 1 << 15, 1 << 18, 1 << 21, 1 << 24):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        x2 = x.reshape(-1, LANES) if n >= LANES else x.reshape(1, -1)
        y2 = y.reshape(-1, LANES) if n >= LANES else y.reshape(1, -1)
        t_naive = _time(_naive_dot, x, y)
        t_kahan = _time(_kahan_dot_lanes, x2, y2)
        ws_kb = 2 * n * 4 / 1024
        rows.append((
            f"throughput/n={n}", f"{t_kahan:.0f}",
            f"ws={ws_kb:.0f}KB naive_us={t_naive:.0f} "
            f"kahan_us={t_kahan:.0f} slowdown={t_kahan/max(t_naive,1e-9):.2f}"
            f" gup_naive={n/max(t_naive,1e-9)/1e3:.2f}"
            f" gup_kahan={n/max(t_kahan,1e-9)/1e3:.2f}",
        ))
    return rows


def main() -> None:
    for r in run():
        print(",".join(str(c) for c in r))


if __name__ == "__main__":
    main()
