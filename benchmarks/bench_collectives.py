"""Compensated cross-device reduction (paper technique at pod scale):
numerics of the ring schedules simulated on host, plus the bandwidth model.

(The real shard_map collectives are exercised on an 8-device mesh in
tests/test_distributed.py; this benchmark isolates the numerics and the
bytes accounting so it runs on one device.)
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import kahan
import jax.numpy as jnp


def _simulate_ring(x: np.ndarray, compensated: bool) -> np.ndarray:
    """x: [n_devices, m]. Sequential-ring reduction order, f32."""
    n = x.shape[0]
    if compensated:
        s = jnp.asarray(x[0])
        c = jnp.zeros_like(s)
        for i in range(1, n):
            s, c = kahan.neumaier_step(s, c, jnp.asarray(x[i]))
        return np.asarray(s + c)
    acc = jnp.asarray(x[0])
    for i in range(1, n):
        acc = acc + jnp.asarray(x[i])
    return np.asarray(acc)


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    for n in (2, 8, 64, 512):
        base = (rng.standard_normal(2048) * 1e5).astype(np.float32)
        shards = np.stack([base * ((-1) ** i) + rng.standard_normal(2048)
                           .astype(np.float32) * 1e-2 for i in range(n)])
        exact = np.sum(np.float64(shards), axis=0)
        err_n = np.abs(_simulate_ring(shards, False) - exact).max()
        err_k = np.abs(_simulate_ring(shards, True) - exact).max()
        # bandwidth model (per chip, ring): psum 2(n-1)/n vs kahan payloads
        psum_traffic = 2 * (n - 1) / n
        kahan_traffic = (1.0 if n == 2
                         else 2 * (n - 1) / n + (n - 1) / n)  # (s,c) RS + AG
        rows.append((
            f"collectives/n={n}", f"{err_k:.3e}",
            f"err_naive={err_n:.3e} err_kahan={err_k:.3e} "
            f"traffic_psum={psum_traffic:.2f}x "
            f"traffic_kahan={kahan_traffic:.2f}x"
            f"{' (free)' if kahan_traffic <= psum_traffic else ''}",
        ))
    # pre-reduce shard statistics (one fused engine pass per shard): the
    # dynamic-range probe that sizes the compensated-vs-plain decision
    from repro.distributed import collectives as C
    st = C.pre_reduce_stats(jnp.asarray(shards[0]), interpret=True)
    rows.append((
        "collectives/pre_reduce_stats", f"{float(st['l2']):.3e}",
        f"sum={float(st['sum']):.3e} l2={float(st['l2']):.3e} "
        f"maxabs={float(st['maxabs']):.3e} (single fused pass)",
    ))
    return rows


def main() -> None:
    for r in run():
        print(",".join(str(c) for c in r))


if __name__ == "__main__":
    main()
