"""Benchmark harness: one module per paper table/figure, CSV output
``name,us_per_call,derived`` per row.

  bench_ecm_predictions   paper §4 / Eqs. 1-3 (ECM cycle predictions)
  bench_accuracy          paper §1 motivation (error vs N, naive vs Kahan)
  bench_kernel_throughput paper Figs. 5-7 analog + unroll (U) sweep,
                          measured vs ECM-predicted (repro.ecm.tpu)
  bench_scaling           paper Figs. 8-9 analog (saturation curves)
  bench_tpu_kahan         DESIGN.md §2.3 (the paper's question on v5e)
  bench_collectives       compensated all-reduce numerics + bandwidth model
  roofline_report         §Roofline table from the dry-run artifacts
"""

from __future__ import annotations

import traceback

from benchmarks import (bench_accuracy, bench_collectives,
                        bench_ecm_predictions, bench_kernel_throughput,
                        bench_scaling, bench_tpu_kahan, roofline_report)

MODULES = [
    bench_ecm_predictions,
    bench_accuracy,
    bench_kernel_throughput,
    bench_scaling,
    bench_tpu_kahan,
    bench_collectives,
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        try:
            for row in mod.run():
                print(",".join(str(c) for c in row), flush=True)
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}")
            traceback.print_exc()
    print("#")
    print("# --- §Roofline table (from results/dryrun) ---")
    try:
        roofline_report.main()
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
