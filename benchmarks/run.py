"""Benchmark harness: one module per paper table/figure, CSV output
``name,us_per_call,derived`` per row.

  bench_ecm_predictions   paper §4 / Eqs. 1-3 (ECM cycle predictions)
  bench_accuracy          paper §1 motivation (error vs N, naive vs Kahan)
  bench_kernel_throughput paper Figs. 5-7 analog + unroll (U) sweep,
                          measured vs ECM-predicted (repro.ecm.tpu)
  bench_scaling           paper Figs. 8-9 analog (saturation curves)
  bench_tpu_kahan         DESIGN.md §2.3 (the paper's question on v5e)
  bench_collectives       compensated all-reduce numerics + bandwidth model
  bench_serving           paged-KV engine: tok/s + KV-bytes-touched
  bench_quant             quantized KV pools: tok/s + bytes + ppl proxy
                          vs kv_dtype, measured vs ECM-predicted speedup
  bench_spec              speculative serving: tok/s + acceptance rate vs
                          the ECM walk-bookkeeping forecast, across
                          proposers / prompt mixes / kv_dtypes / k
  roofline_report         §Roofline table from the dry-run artifacts
                          (one row per cell; skips when artifacts absent)

CLI:
  --only SUBSTR   run only modules whose name contains SUBSTR (repeatable)
  --json [PATH]   also write rows as JSON [{name, us_per_call, derived}]
                  — the CI smoke step's perf-trajectory artifact. With no
                  PATH the name is derived deterministically from the git
                  commit (BENCH_<shortsha>.json) so the CI workflow can
                  commit it and the trajectory accumulates in-repo.
  --compare PREV.json
                  regression gate: after running, compare every series
                  that reports ``tok_s=`` against the same series in a
                  previous trajectory JSON and exit nonzero when any
                  shared series lost more than --compare-tolerance of
                  its throughput. Series only one side has are ignored
                  (benches come and go); CI feeds the last committed
                  BENCH_*.json so a PR cannot silently land a tok/s
                  cliff.
  --compare-tolerance FRAC   allowed fractional loss (default 0.20)
"""

from __future__ import annotations

import argparse
import json
import re
import subprocess
import traceback

from benchmarks import (bench_accuracy, bench_collectives,
                        bench_ecm_predictions, bench_kernel_throughput,
                        bench_quant, bench_scaling, bench_serving,
                        bench_spec, bench_tpu_kahan, roofline_report)

MODULES = [
    bench_ecm_predictions,
    bench_accuracy,
    bench_kernel_throughput,
    bench_scaling,
    bench_tpu_kahan,
    bench_collectives,
    bench_serving,
    bench_quant,
    bench_spec,
    roofline_report,
]


def default_json_path() -> str:
    """Deterministic perf-trajectory filename for the current commit —
    the same commit always maps to the same BENCH_*.json, so re-runs
    overwrite instead of multiplying artifacts."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip()
    except Exception:
        sha = ""
    return f"BENCH_{sha or 'local'}.json"


def _tok_s(derived: str) -> float | None:
    m = re.search(r"\btok_s=([0-9.]+)", derived or "")
    return float(m.group(1)) if m else None


def find_regressions(current: list[dict], prev_path: str,
                     tolerance: float = 0.20) -> tuple[list[tuple], int]:
    """Compare ``tok_s=`` across series shared with a previous trajectory
    JSON. Returns (regressions as (name, was, now), shared-series count).
    Wall-clock on shared CI runners is noisy, so the gate is a wide one —
    it exists to catch step-function cliffs (an accidental recompile per
    step, a dtype falling off the fast path), not single-digit drift."""
    with open(prev_path) as f:
        prev = json.load(f)
    ref = {r["name"]: _tok_s(r.get("derived", "")) for r in prev}
    regressions, shared = [], 0
    for row in current:
        was, now = ref.get(row["name"]), _tok_s(row.get("derived", ""))
        if was and now:
            shared += 1
            if now < was * (1.0 - tolerance):
                regressions.append((row["name"], was, now))
    return regressions, shared


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None, metavar="SUBSTR",
                    help="run only modules whose name contains SUBSTR")
    ap.add_argument("--json", default=None, metavar="PATH", nargs="?",
                    const="auto",
                    help="also write results as JSON; omit PATH for the "
                         "deterministic per-commit BENCH_<shortsha>.json")
    ap.add_argument("--compare", default=None, metavar="PREV.json",
                    help="exit nonzero if any shared tok_s series lost "
                         "more than --compare-tolerance vs this trajectory")
    ap.add_argument("--compare-tolerance", type=float, default=0.20,
                    metavar="FRAC", help="allowed fractional tok/s loss")
    args = ap.parse_args()
    if args.json == "auto":
        args.json = default_json_path()

    modules = MODULES
    if args.only:
        modules = [m for m in MODULES
                   if any(s in m.__name__ for s in args.only)]
        if not modules:
            raise SystemExit(f"--only {args.only}: no module matches "
                             f"(have {[m.__name__ for m in MODULES]})")

    print("name,us_per_call,derived")
    collected = []
    failures = 0
    for mod in modules:
        try:
            for row in mod.run():
                print(",".join(str(c) for c in row), flush=True)
                collected.append({"name": row[0],
                                  "us_per_call": row[1],
                                  "derived": row[2] if len(row) > 2 else ""})
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}")
            traceback.print_exc()
    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}")
    if args.compare is not None:
        regressions, shared = find_regressions(collected, args.compare,
                                               args.compare_tolerance)
        for name, was, now in regressions:
            print(f"# REGRESSION {name}: tok_s {was:.1f} -> {now:.1f} "
                  f"({now / was - 1.0:+.0%})")
        if regressions:
            raise SystemExit(
                f"{len(regressions)} of {shared} shared series regressed "
                f">{args.compare_tolerance:.0%} vs {args.compare}")
        print(f"# compare vs {args.compare}: {shared} shared series "
              f"within {args.compare_tolerance:.0%}")
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
