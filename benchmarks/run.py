"""Benchmark harness: one module per paper table/figure, CSV output
``name,us_per_call,derived`` per row.

  bench_ecm_predictions   paper §4 / Eqs. 1-3 (ECM cycle predictions)
  bench_accuracy          paper §1 motivation (error vs N, naive vs Kahan)
  bench_kernel_throughput paper Figs. 5-7 analog + unroll (U) sweep,
                          measured vs ECM-predicted (repro.ecm.tpu)
  bench_scaling           paper Figs. 8-9 analog (saturation curves)
  bench_tpu_kahan         DESIGN.md §2.3 (the paper's question on v5e)
  bench_collectives       compensated all-reduce numerics + bandwidth model
  bench_serving           paged-KV engine: tok/s + KV-bytes-touched
  bench_quant             quantized KV pools: tok/s + bytes + ppl proxy
                          vs kv_dtype, measured vs ECM-predicted speedup
  bench_spec              speculative serving: tok/s + acceptance rate vs
                          the ECM walk-bookkeeping forecast, across
                          proposers / prompt mixes / kv_dtypes / k
  roofline_report         §Roofline table from the dry-run artifacts
                          (one row per cell); with no artifacts, falls
                          back to LIVE attribution rows from a profiled
                          engine (roofline/live/<phase>)

CLI:
  --only SUBSTR   run only modules whose name contains SUBSTR (repeatable)
  --json [PATH]   also write rows as JSON [{name, us_per_call, derived}]
                  — the CI smoke step's perf-trajectory artifact. With no
                  PATH the name is derived deterministically from the git
                  commit (BENCH_<shortsha>.json) so the CI workflow can
                  commit it and the trajectory accumulates in-repo.
  --compare PREV.json
                  regression gate, two tiers. DETERMINISTIC COUNTER
                  series (bytes/tokens moved, hit rates, acceptance
                  rates — the ``kv_stats``-derived fields listed in
                  ``DETERMINISTIC_FIELDS``, plus counter-basis
                  ``ecm_residual/`` rows) must match the previous
                  trajectory to ~1e-6 relative: a seeded workload
                  reproduces them bitwise, so any mismatch is a real
                  code/workload change and the gate exits nonzero.
                  WALL-CLOCK series (``tok_s=``) that lost more than
                  --compare-tolerance while every counter still matches
                  are reported as ``# POSSIBLE HOST DRIFT`` without
                  failing — counters unmoved means the engine did the
                  same work, so the delta lives on the host, not in the
                  code. Series only one side has are ignored (benches
                  come and go); CI feeds the last committed BENCH_*.json.

                  Drift calibration: every run opens with a
                  ``calibration/kahan_dot_ref`` row — a pinned-shape
                  Kahan-dot reference kernel whose ratio to the
                  committed constant (repro.obs.profile
                  .CALIBRATION_REF_S) is this run's
                  ``host_drift_factor``, stamped on every wallclock row
                  and residual. The gate normalizes both sides' tok/s
                  by their factors before judging drift: a loss that
                  disappears under normalization is drift-EXPLAINED
                  (the reference kernel slowed down by the same ratio)
                  and, when every drift line is explained, the run
                  exits with the distinct code ``DRIFT_EXIT_CODE`` (4)
                  so CI can tell "host was slow" from "code got slow".
                  Counter-basis rows stay gated at 1e-6 regardless.
  --compare-tolerance FRAC   allowed fractional tok/s loss before a
                  host-drift report (default 0.20)
"""

from __future__ import annotations

import argparse
import json
import re
import subprocess
import traceback

from benchmarks import (bench_accuracy, bench_collectives,
                        bench_ecm_predictions, bench_kernel_throughput,
                        bench_quant, bench_scaling, bench_serving,
                        bench_spec, bench_tpu_kahan, roofline_report)

MODULES = [
    bench_ecm_predictions,
    bench_accuracy,
    bench_kernel_throughput,
    bench_scaling,
    bench_tpu_kahan,
    bench_collectives,
    bench_serving,
    bench_quant,
    bench_spec,
    roofline_report,
]


def default_json_path() -> str:
    """Deterministic perf-trajectory filename for the current commit —
    the same commit always maps to the same BENCH_*.json, so re-runs
    overwrite instead of multiplying artifacts."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip()
    except Exception:
        sha = ""
    return f"BENCH_{sha or 'local'}.json"


def _tok_s(derived: str) -> float | None:
    m = re.search(r"\btok_s=([0-9.]+)", derived or "")
    return float(m.group(1)) if m else None


# The drift-calibration anchor row every trajectory JSON opens with, and
# the distinct exit code --compare uses when host drift (not a code
# regression) explains every flagged tok/s loss.
CALIBRATION_ROW = "calibration/kahan_dot_ref"
DRIFT_EXIT_CODE = 4


def calibration_row() -> tuple:
    """Measure the pinned-shape Kahan-dot reference at bench start; the
    ratio to the committed constant is this run's host_drift_factor."""
    from repro.obs import profile as obs_profile
    cal = obs_profile.calibrate()
    return (CALIBRATION_ROW, f"{cal.ref_s * 1e6:.0f}",
            f"host_drift_factor={cal.host_drift_factor:.3f}"
            f" dispatch_us={cal.dispatch_s * 1e6:.1f}"
            f" machine_scale={cal.machine_scale:.1f}"
            f" elems={cal.elems}")


def _is_wallclock_row(derived: str) -> bool:
    """Rows whose headline numbers come off the wall clock — the ones
    that carry (and can be normalized by) a host_drift_factor."""
    return ("tok_s=" in (derived or "")
            or "basis=wallclock" in (derived or ""))


def _drift_factor(rows: list[dict]) -> float | None:
    """The host_drift_factor recorded by a trajectory's calibration row
    (None for pre-calibration trajectories)."""
    for r in rows:
        if r.get("name") == CALIBRATION_ROW:
            f = _fields(r.get("derived", "")).get("host_drift_factor")
            if f:
                return f
    return None


# key=value fields in derived strings; numeric values may carry an 'x'
# suffix (ratios) and scientific notation.
_FIELD_RE = re.compile(
    r"\b([A-Za-z_][A-Za-z0-9_]*)=(-?[0-9]+(?:\.[0-9]+)?(?:e[+-]?[0-9]+)?)x?"
    r"(?=\s|$)")

# Derived fields computed purely from the engines' deterministic
# counters (kv_stats / swap / prefix-cache / spec accounting) on seeded
# workloads. These reproduce to the printed precision on any host —
# a mismatch against the previous trajectory is a code or workload
# change, never noise, so the compare gate hard-fails on it.
# Wall-clock-derived fields (tok_s, speedup, read_gbps, us_per_call)
# are deliberately NOT here.
DETERMINISTIC_FIELDS = frozenset({
    "paged_kv_kib", "contig_kv_kib", "kv_reduction", "prefix_hit",
    "hit_rate", "prefill_tok_reduction", "saved_kv_kib", "cow_blocks",
    "preempted", "swapped_blocks", "restored_blocks", "guard_trips",
    "host_kib", "acc", "E", "elems",
    # session-KV counters (serving/session rows): turn-2+ whole-history
    # hit tokens/rate, spill-tier traffic, and the promote-vs-never
    # prefill-token ratio — all derived from seeded token counters
    "turn2_hit", "turn2_hit_rate", "hit_rate_nopromote",
    "spilled_blocks", "promoted_blocks", "promoted_tokens",
    "promote_gain",
})


def _fields(derived: str) -> dict[str, float]:
    return {k: float(v) for k, v in _FIELD_RE.findall(derived or "")}


def _gated_counters(name: str, fields: dict) -> dict[str, float]:
    """The subset of a row's fields the deterministic gate covers.
    Counter-basis ``ecm_residual/`` rows gate their predicted AND
    measured sides (both are functions of deterministic inputs);
    wallclock-basis residuals gate nothing."""
    if name.startswith("ecm_residual/"):
        if fields.get("basis") == "counter":
            return {k: fields[k] for k in ("predicted", "measured")
                    if k in fields}
        return {}
    return {k: v for k, v in fields.items() if k in DETERMINISTIC_FIELDS}


def find_regressions(current: list[dict], prev_path: str,
                     tolerance: float = 0.20) -> tuple[list, list, int]:
    """Two-tier comparison against a previous trajectory JSON.

    Returns (counter_mismatches, drift, shared) where
    ``counter_mismatches`` is [(name, field, was, now)] for every
    deterministic counter that moved beyond ~1e-6 relative (hard
    failures), ``drift`` is [(name, was, now, explained)] for shared
    ``tok_s`` series that lost more than ``tolerance`` (reported as
    possible host drift — wall clock on shared runners is noisy, and
    with counters unmoved the engine provably did the same work), and
    ``shared`` is the shared-series count.

    ``explained`` is True when normalizing both sides by their runs'
    measured ``host_drift_factor`` (the calibration rows) brings the
    loss back inside ``tolerance`` — the reference kernel slowed by the
    same ratio the workload did, so the host, not the code, moved.
    False when normalization does NOT recover it, or when either side
    predates the calibration row (nothing to normalize by)."""
    with open(prev_path) as f:
        prev = json.load(f)
    ref = {r["name"]: r.get("derived", "") for r in prev}
    hdf_prev, hdf_now = _drift_factor(prev), _drift_factor(current)
    mismatches, drift, shared = [], [], 0
    for row in current:
        name = row["name"]
        if name not in ref:
            continue
        shared += 1
        prev_fields = _fields(ref[name])
        now_fields = _fields(row.get("derived", ""))
        # basis= is a word, not a number — recover it for residual rows
        for src, dst in ((ref[name], prev_fields),
                         (row.get("derived", ""), now_fields)):
            m = re.search(r"\bbasis=(\w+)", src or "")
            if m:
                dst["basis"] = m.group(1)
        gated = _gated_counters(name, now_fields)
        for field, now_v in gated.items():
            was_v = _gated_counters(name, prev_fields).get(field)
            if was_v is None:
                continue    # field newly added to the row format
            if abs(now_v - was_v) > 1e-6 * max(abs(was_v), 1e-9):
                mismatches.append((name, field, was_v, now_v))
        was, now = _tok_s(ref[name]), _tok_s(row.get("derived", ""))
        if was and now and now < was * (1.0 - tolerance):
            explained = False
            if hdf_prev and hdf_now:
                # normalize to reference-host tok/s: a slower host has
                # factor > 1, and tok_s * factor recovers what the
                # reference host would have measured
                explained = (now * hdf_now
                             >= was * hdf_prev * (1.0 - tolerance))
            drift.append((name, was, now, explained))
    return mismatches, drift, shared


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None, metavar="SUBSTR",
                    help="run only modules whose name contains SUBSTR")
    ap.add_argument("--json", default=None, metavar="PATH", nargs="?",
                    const="auto",
                    help="also write results as JSON; omit PATH for the "
                         "deterministic per-commit BENCH_<shortsha>.json")
    ap.add_argument("--compare", default=None, metavar="PREV.json",
                    help="exit nonzero if any shared tok_s series lost "
                         "more than --compare-tolerance vs this trajectory")
    ap.add_argument("--compare-tolerance", type=float, default=0.20,
                    metavar="FRAC", help="allowed fractional tok/s loss")
    args = ap.parse_args()
    if args.json == "auto":
        args.json = default_json_path()

    modules = MODULES
    if args.only:
        modules = [m for m in MODULES
                   if any(s in m.__name__ for s in args.only)]
        if not modules:
            raise SystemExit(f"--only {args.only}: no module matches "
                             f"(have {[m.__name__ for m in MODULES]})")

    print("name,us_per_call,derived")
    collected = []
    failures = 0
    # drift calibration first: the pinned Kahan-dot reference anchors
    # every wallclock row below to this host's measured speed
    hdf = None
    try:
        cal_row = calibration_row()
        print(",".join(str(c) for c in cal_row), flush=True)
        collected.append({"name": cal_row[0], "us_per_call": cal_row[1],
                          "derived": cal_row[2]})
        hdf = _fields(cal_row[2]).get("host_drift_factor")
    except Exception:
        failures += 1
        print("# FAILED calibration")
        traceback.print_exc()
    for mod in modules:
        try:
            for row in mod.run():
                derived = str(row[2]) if len(row) > 2 else ""
                if hdf is not None and _is_wallclock_row(derived):
                    derived += f" host_drift_factor={hdf:.3f}"
                print(",".join([str(row[0]), str(row[1]), derived]),
                      flush=True)
                collected.append({"name": row[0],
                                  "us_per_call": row[1],
                                  "derived": derived})
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}")
            traceback.print_exc()
    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}")
    if args.compare is not None:
        mismatches, drift, shared = find_regressions(
            collected, args.compare, args.compare_tolerance)
        for name, field, was, now in mismatches:
            print(f"# COUNTER MISMATCH {name}: {field} {was:g} -> {now:g}")
        hdf_txt = f"{hdf:.3f}" if hdf is not None else "n/a"
        for name, was, now, explained in drift:
            verdict = ("drift-EXPLAINED: loss disappears after "
                       "host_drift_factor normalization" if explained
                       else "NOT explained by measured drift")
            print(f"# POSSIBLE HOST DRIFT {name}: tok_s {was:.1f} -> "
                  f"{now:.1f} ({now / was - 1.0:+.0%}) "
                  f"host_drift_factor={hdf_txt} — deterministic "
                  f"counters unchanged, so the engine did the same "
                  f"work; {verdict}")
        if mismatches:
            raise SystemExit(
                f"{len(mismatches)} deterministic counter(s) moved vs "
                f"{args.compare} — seeded workloads reproduce these "
                f"bitwise; this is a code or workload change, not noise")
        print(f"# compare vs {args.compare}: {shared} shared series, "
              f"counters match; {len(drift)} possible host-drift "
              f"series (>{args.compare_tolerance:.0%} tok/s loss, "
              f"not gating)")
        if drift and all(x[3] for x in drift):
            # every flagged loss is the host's, not the code's: exit
            # with the distinct drift code so CI can record (and
            # tolerate) a slow-runner episode explicitly
            print(f"# exiting {DRIFT_EXIT_CODE}: host drift explains "
                  f"every flagged series")
            raise SystemExit(DRIFT_EXIT_CODE)
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
