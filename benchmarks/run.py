"""Benchmark harness: one module per paper table/figure, CSV output
``name,us_per_call,derived`` per row.

  bench_ecm_predictions   paper §4 / Eqs. 1-3 (ECM cycle predictions)
  bench_accuracy          paper §1 motivation (error vs N, naive vs Kahan)
  bench_kernel_throughput paper Figs. 5-7 analog + unroll (U) sweep,
                          measured vs ECM-predicted (repro.ecm.tpu)
  bench_scaling           paper Figs. 8-9 analog (saturation curves)
  bench_tpu_kahan         DESIGN.md §2.3 (the paper's question on v5e)
  bench_collectives       compensated all-reduce numerics + bandwidth model
  bench_serving           paged-KV engine: tok/s + KV-bytes-touched
  bench_quant             quantized KV pools: tok/s + bytes + ppl proxy
                          vs kv_dtype, measured vs ECM-predicted speedup
  bench_spec              speculative serving: tok/s + acceptance rate vs
                          the ECM walk-bookkeeping forecast, across
                          proposers / prompt mixes / kv_dtypes / k
  roofline_report         §Roofline table from the dry-run artifacts
                          (one row per cell; skips when artifacts absent)

CLI:
  --only SUBSTR   run only modules whose name contains SUBSTR (repeatable)
  --json [PATH]   also write rows as JSON [{name, us_per_call, derived}]
                  — the CI smoke step's perf-trajectory artifact. With no
                  PATH the name is derived deterministically from the git
                  commit (BENCH_<shortsha>.json) so the CI workflow can
                  commit it and the trajectory accumulates in-repo.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import traceback

from benchmarks import (bench_accuracy, bench_collectives,
                        bench_ecm_predictions, bench_kernel_throughput,
                        bench_quant, bench_scaling, bench_serving,
                        bench_spec, bench_tpu_kahan, roofline_report)

MODULES = [
    bench_ecm_predictions,
    bench_accuracy,
    bench_kernel_throughput,
    bench_scaling,
    bench_tpu_kahan,
    bench_collectives,
    bench_serving,
    bench_quant,
    bench_spec,
    roofline_report,
]


def default_json_path() -> str:
    """Deterministic perf-trajectory filename for the current commit —
    the same commit always maps to the same BENCH_*.json, so re-runs
    overwrite instead of multiplying artifacts."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip()
    except Exception:
        sha = ""
    return f"BENCH_{sha or 'local'}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None, metavar="SUBSTR",
                    help="run only modules whose name contains SUBSTR")
    ap.add_argument("--json", default=None, metavar="PATH", nargs="?",
                    const="auto",
                    help="also write results as JSON; omit PATH for the "
                         "deterministic per-commit BENCH_<shortsha>.json")
    args = ap.parse_args()
    if args.json == "auto":
        args.json = default_json_path()

    modules = MODULES
    if args.only:
        modules = [m for m in MODULES
                   if any(s in m.__name__ for s in args.only)]
        if not modules:
            raise SystemExit(f"--only {args.only}: no module matches "
                             f"(have {[m.__name__ for m in MODULES]})")

    print("name,us_per_call,derived")
    collected = []
    failures = 0
    for mod in modules:
        try:
            for row in mod.run():
                print(",".join(str(c) for c in row), flush=True)
                collected.append({"name": row[0],
                                  "us_per_call": row[1],
                                  "derived": row[2] if len(row) > 2 else ""})
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}")
            traceback.print_exc()
    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}")
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
