"""§Roofline table generator: reads results/dryrun/*.json, prints the
three-term roofline per (arch × shape × mesh) cell and writes the markdown
table consumed by EXPERIMENTS.md.

When no dry-run artifacts exist, the harness path (``run()``) no longer
just skips: it runs a CPU-tiny profiled DecodeEngine (the PR-9 ECM
attribution profiler, ``Telemetry(profile=True)``) and emits one
``roofline/live/<phase>`` row per engine phase from the LIVE attribution
— bound category plus the compiled-HLO flops/bytes counters that priced
it. Live rows are wallclock-adjacent (the bound can flip with host load)
so they are deliberately not in the deterministic gate set; the counter
columns themselves are seeded-deterministic."""

from __future__ import annotations

import glob
import json
import os


def load_cells(dryrun_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    if x >= 1e-6:
        return f"{x*1e6:.1f}us"
    return f"{x*1e9:.0f}ns"


def markdown_table(cells: list[dict], *, mesh: str = "16x16") -> str:
    rows = [c for c in cells if c["mesh"] == mesh
            and c.get("variant", "kahan") == "kahan"]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = [
        "| arch | shape | T_compute | T_memory | T_collective | bound | "
        "useful FLOP ratio | roofline frac | bytes/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        out.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_s(c['t_compute_s'])} | "
            f"{_fmt_s(c['t_memory_s'])} | {_fmt_s(c['t_collective_s'])} | "
            f"{c['dominant']} | {c['useful_flop_ratio']:.3f} | "
            f"{c['roofline_fraction']:.4f} | "
            f"{c['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.1f}GB |")
    return "\n".join(out)


def summary(cells: list[dict]) -> dict:
    by_dominant: dict = {}
    for c in cells:
        by_dominant.setdefault(c["dominant"], []).append(
            (c["arch"], c["shape"], c["mesh"]))
    return by_dominant


def live_attribution_rows() -> list[tuple]:
    """Roofline from the live engine: run the seeded 2-layer serving
    workload under a profiling Telemetry and turn each phase's ECM
    attribution into a ``roofline/live/<phase>`` row. This is the
    profiler consuming its own measurement — no dry-run artifact, the
    flops/bytes come from the compiled HLO of the launches that actually
    ran."""
    import jax

    from repro import obs
    from repro.configs import get_config, reduced
    from repro.models import api, common
    from repro.serving.engine import DecodeEngine, Request

    cfg = reduced(get_config("qwen1.5-0.5b")).with_(num_layers=2)
    params = common.init_params(api.schema(cfg), jax.random.key(0))
    tele = obs.Telemetry(wall_clock=True, profile=True)
    tele.profile.calibrate()
    engine = DecodeEngine(cfg, params, max_slots=2, max_context=128,
                          block_size=16, prefill_chunk=32,
                          telemetry=tele)
    import numpy as np
    rng = np.random.default_rng(7)
    for wave in range(2):           # wave 0 warms jit + HLO-cost caches
        for i in range(3):
            prompt = rng.integers(1, 250, 24 + 8 * i).tolist()
            engine.submit(Request(rid=10 * wave + i, prompt=prompt,
                                  max_new_tokens=4))
        if wave:
            tele.profile.reset()
        engine.run_until_done()
    rows = []
    for a in sorted(tele.profile.attribution(), key=lambda a: a.phase):
        rows.append((f"roofline/live/{a.phase}", f"{a.wall_s * 1e6:.1f}",
                     f"bound={a.bound} calls={a.calls}"
                     f" flops={a.flops:.0f} hbm_bytes={a.hbm_bytes:.0f}"
                     f" host_bytes={a.host_bytes:.0f}"))
    return rows


def run() -> list[tuple]:
    """Harness-addressable form (benchmarks/run.py --only roofline): one
    CSV row per dry-run cell. With no results/dryrun artifacts, falls
    back to live attribution from a profiled engine instead of
    skipping."""
    cells = load_cells()
    if not cells:
        return live_attribution_rows()
    rows = []
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        t_total = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
        rows.append((f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                     f"{t_total * 1e6:.1f}",
                     f"bound={c['dominant']}"
                     f" roofline_frac={c['roofline_fraction']:.4f}"))
    return rows


def main() -> None:
    cells = load_cells()
    if not cells:
        print("no dryrun results found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both")
        return
    print(f"# {len(cells)} dry-run cells\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## mesh {mesh}\n")
        print(markdown_table(cells, mesh=mesh))
    print("\n## dominant-term census")
    for k, v in summary(cells).items():
        print(f"  {k}: {len(v)} cells")


if __name__ == "__main__":
    main()
