"""§Roofline table generator: reads results/dryrun/*.json, prints the
three-term roofline per (arch × shape × mesh) cell and writes the markdown
table consumed by EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os


def load_cells(dryrun_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    if x >= 1e-6:
        return f"{x*1e6:.1f}us"
    return f"{x*1e9:.0f}ns"


def markdown_table(cells: list[dict], *, mesh: str = "16x16") -> str:
    rows = [c for c in cells if c["mesh"] == mesh
            and c.get("variant", "kahan") == "kahan"]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = [
        "| arch | shape | T_compute | T_memory | T_collective | bound | "
        "useful FLOP ratio | roofline frac | bytes/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        out.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_s(c['t_compute_s'])} | "
            f"{_fmt_s(c['t_memory_s'])} | {_fmt_s(c['t_collective_s'])} | "
            f"{c['dominant']} | {c['useful_flop_ratio']:.3f} | "
            f"{c['roofline_fraction']:.4f} | "
            f"{c['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.1f}GB |")
    return "\n".join(out)


def summary(cells: list[dict]) -> dict:
    by_dominant: dict = {}
    for c in cells:
        by_dominant.setdefault(c["dominant"], []).append(
            (c["arch"], c["shape"], c["mesh"]))
    return by_dominant


def run() -> list[tuple]:
    """Harness-addressable form (benchmarks/run.py --only roofline): one
    CSV row per dry-run cell. Skips cleanly — a single informative row,
    no failure — when no results/dryrun artifacts exist."""
    cells = load_cells()
    if not cells:
        return [("roofline/cells", "0",
                 "skipped: no results/dryrun artifacts (run "
                 "repro.launch.dryrun first)")]
    rows = []
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        t_total = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
        rows.append((f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                     f"{t_total * 1e6:.1f}",
                     f"bound={c['dominant']}"
                     f" roofline_frac={c['roofline_fraction']:.4f}"))
    return rows


def main() -> None:
    cells = load_cells()
    if not cells:
        print("no dryrun results found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both")
        return
    print(f"# {len(cells)} dry-run cells\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## mesh {mesh}\n")
        print(markdown_table(cells, mesh=mesh))
    print("\n## dominant-term census")
    for k, v in summary(cells).items():
        print(f"  {k}: {len(v)} cells")


if __name__ == "__main__":
    main()
